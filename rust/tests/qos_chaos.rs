//! Churn-chaos lane for the QoS gateway (ISSUE 7 tentpole, part d):
//! fixture-based, artifact-free, tier-1.
//!
//! The scenario: a gateway under sustained **open-loop** traffic — the
//! only drive mode where offered load does not self-throttle, so SLO
//! gates genuinely shed — while sessions are hot-opened and hot-closed
//! mid-drive and the shared weight store thrashes under a deliberately
//! tiny `--weight-budget`.  The contracts under test:
//!
//! * **Exact accounting**: `served + shed + failed == offered`, with
//!   every non-served request a typed [`FailureKind::Shed`] record —
//!   reject-don't-collapse, nothing silently dropped, even while the
//!   routed session disappears and reappears under the driver.
//! * **Bit-identity under duress**: every served logit vector is
//!   bit-identical to a direct [`NativeBackend`] reference for the same
//!   `(format, sample)` — shedding, priority scheduling, store
//!   eviction, and churn may refuse work but may never perturb it.
//! * **Liveness**: the drive, the churn thread, and shutdown all
//!   complete — no deadlock between the permit scheduler, the
//!   dispatchers, and session teardown (the test finishing is the
//!   assertion).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use precis::formats::{Format, PrecisionSpec};
use precis::nn::Network;
use precis::serving::{
    drive_open_loop, warm_up, ArrivalSchedule, Backend, DriveReport, FailureKind, Gateway,
    NativeBackend, QosScheduler, Session, SessionKey, SessionOptions, ShedReason, SloTarget,
};
use precis::store::{StoreEntry, WeightStore};
use precis::tensor::Tensor;
use precis::testing::fixtures::tiny_network;

const EVAL_N: usize = 8;

/// A native backend slowed to `delay` per batch: capacity is a test
/// parameter, so a fast arrival schedule *provably* exceeds it and the
/// depth gate must shed — no timing luck involved.
struct SlowBackend {
    inner: NativeBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        self.inner.run_spec(x, spec)
    }
    fn network(&self) -> &Arc<Network> {
        self.inner.network()
    }
    fn label(&self) -> &'static str {
        "native"
    }
    fn store_stats(&self) -> Option<precis::store::StoreStats> {
        self.inner.store_stats()
    }
}

/// An SLO-gated session over the shared (budget-capped) weight store
/// and the shared permit scheduler, executing one request per batch at
/// `delay` per batch.
fn slow_session(
    net: &Arc<Network>,
    fmt: Format,
    slo: SloTarget,
    store: &Arc<WeightStore>,
    sched: &Arc<QosScheduler>,
    delay: Duration,
) -> Session {
    let n = net.clone();
    let st = store.clone();
    let opts = SessionOptions {
        batch: 1,
        max_wait: Duration::from_millis(0),
        slo: Some(slo),
        ..SessionOptions::default()
    };
    Session::with_factory_qos(
        net.clone(),
        fmt,
        opts,
        Some(sched.clone()),
        Box::new(move || {
            let inner = NativeBackend::with_store(n, st);
            Ok(Box::new(SlowBackend { inner, delay }) as Box<dyn Backend>)
        }),
    )
}

/// A weight-store budget that admits any single staged entry of the
/// fixture's `fc` layer but cannot hold two formats' entries at once —
/// every cross-format batch alternation evicts (the `--weight-budget`
/// thrash lane).
fn thrash_budget(fmts: &[Format]) -> usize {
    let w_len = 4 * 3; // tiny_network fc: 4 -> 3
    fmts.iter().map(|f| StoreEntry::bytes_for(w_len, f)).max().unwrap() + 8
}

/// Bit-identity of every served logit vector against a direct
/// [`NativeBackend`] run of the same `(format, sample)` — computed on a
/// fresh, unbounded store, so it also cross-checks the store contract
/// (hits, misses, and evicted-then-restaged entries all agree).
fn assert_served_bit_identical(report: &DriveReport, net: &Arc<Network>, fmts: &[Format]) {
    let refs: Vec<Tensor> = fmts
        .iter()
        .map(|fmt| {
            NativeBackend::new(net.clone())
                .run_batch(&net.eval_x.slice_rows(0, EVAL_N), fmt)
                .unwrap()
        })
        .collect();
    for (ki, sample, _, logits) in &report.served {
        let want = &refs[*ki].data()[sample * net.classes..(sample + 1) * net.classes];
        assert_eq!(logits.len(), want.len());
        for (j, (a, b)) in logits.iter().zip(want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "key {ki} sample {sample} logit {j}: served logits must be bit-identical"
            );
        }
    }
}

/// Every failure must be a typed shed (admission control or a closed
/// key) — an execution failure under chaos would be a real bug.
fn assert_failures_are_typed_sheds(report: &DriveReport) {
    for f in &report.failures {
        match &f.kind {
            FailureKind::Shed(_) => {}
            FailureKind::Failed(msg) => panic!("request {} failed outright: {msg}", f.index),
        }
    }
}

/// Part 1 (no churn yet): burst arrivals far above the throttled
/// service rate force depth sheds; the books balance exactly, the gate
/// counters agree with the driver's records, served responses stay
/// bit-exact, and the tiny shared budget provably thrashed.
#[test]
fn open_loop_burst_sheds_exactly_and_serves_bit_exact() {
    let net = tiny_network(EVAL_N);
    let fmts = [Format::float(7, 6), Format::fixed(8, 8)];
    let store = Arc::new(WeightStore::with_budget(thrash_budget(&fmts)));
    let sched = QosScheduler::new(1); // one execution slot gateway-wide
    let slo = SloTarget::new(10_000.0, 4).unwrap(); // depth-gated only
    let delay = Duration::from_millis(3);

    let gw = Gateway::empty();
    let keys: Vec<SessionKey> = fmts
        .iter()
        .map(|&fmt| gw.adopt(slow_session(&net, fmt, slo, &store, &sched, delay)))
        .collect();
    warm_up(&gw, &keys).unwrap();

    // ~200 fires in a few ms of schedule against a ~333 req/s service
    // rate: the depth bound (4/session) must shed most of the stream.
    let sched_arrivals = ArrivalSchedule::parse("burst:1000rps:50000rps:20ms:0.5", 2018).unwrap();
    let report = drive_open_loop(&gw, &keys, &sched_arrivals, 200);

    assert_eq!(report.offered, 200);
    assert!(
        report.is_balanced(),
        "served {} + shed {} + failed {} != offered {}",
        report.served.len(),
        report.shed(),
        report.failed(),
        report.offered
    );
    assert_failures_are_typed_sheds(&report);
    assert_eq!(report.failed(), 0);
    assert!(report.shed() > 0, "over-capacity open-loop drive must shed");
    // the first fire per key lands in an empty queue: always admitted
    assert!(report.served.len() >= keys.len());

    assert_served_bit_identical(&report, &net, &fmts);

    // driver records and gate counters are the same books: no session
    // vanished here, so every shed is an admission-control shed
    let gate_shed: u64 = keys.iter().map(|k| gw.session(k).unwrap().stats().shed).sum();
    assert_eq!(gate_shed, report.shed());

    // the tiny budget cannot hold both formats' staged entries: the
    // alternating batches provably evicted (--weight-budget thrash)
    let st = store.stats();
    assert!(st.evictions > 0, "expected store thrash, got {}", st.render());

    // full drain on shutdown: depth gauges return to zero
    let fin = gw.shutdown();
    for (key, s) in &fin.sessions {
        assert_eq!(s.depth, 0, "{key} retired with phantom backlog");
    }
}

/// The chaos lane proper: sustained open-loop traffic while one session
/// is hot-closed and re-adopted in a loop.  Accounting stays exact,
/// nothing fails outright, every served logit stays bit-identical, and
/// everything shuts down (liveness).
#[test]
fn churn_under_open_loop_traffic_keeps_books_exact() {
    let net = tiny_network(EVAL_N);
    let fmts = [Format::float(7, 6), Format::fixed(8, 8), Format::float(4, 5)];
    let store = Arc::new(WeightStore::with_budget(thrash_budget(&fmts)));
    let sched = QosScheduler::new(1);
    let slo = SloTarget::new(10_000.0, 4).unwrap();
    let delay = Duration::from_millis(2);

    let gw = Gateway::empty();
    let keys: Vec<SessionKey> = fmts
        .iter()
        .map(|&fmt| gw.adopt(slow_session(&net, fmt, slo, &store, &sched, delay)))
        .collect();
    warm_up(&gw, &keys).unwrap();

    let churn_fmt = fmts[2];
    let churn_key = keys[2].clone();
    let stop = AtomicBool::new(false);
    let arrivals = ArrivalSchedule::parse("poisson:20000rps", 7).unwrap();

    let report = std::thread::scope(|scope| {
        let churner = scope.spawn(|| {
            // hot close/re-open the third session for as long as the
            // drive runs
            let mut cycles = 0u32;
            let mut closed = 0u32;
            while !stop.load(Ordering::Acquire) {
                if gw.close(&churn_key).is_some() {
                    closed += 1;
                }
                std::thread::sleep(Duration::from_millis(1));
                let again = slow_session(&net, churn_fmt, slo, &store, &sched, delay);
                assert_eq!(gw.adopt(again), churn_key, "key must be stable across re-adoption");
                cycles += 1;
            }
            assert_eq!(closed, cycles, "every cycle must find the re-adopted session to close");
            cycles
        });
        let report = drive_open_loop(&gw, &keys, &arrivals, 300);
        stop.store(true, Ordering::Release);
        let cycles = churner.join().unwrap();
        assert!(cycles > 0, "the churn thread must have cycled at least once");
        report
    });

    assert_eq!(report.offered, 300);
    assert!(
        report.is_balanced(),
        "served {} + shed {} + failed {} != offered {}",
        report.served.len(),
        report.shed(),
        report.failed(),
        report.offered
    );
    assert_failures_are_typed_sheds(&report);
    assert!(report.shed() > 0);
    assert!(!report.served.is_empty());
    assert_served_bit_identical(&report, &net, &fmts);

    // liveness: shutdown drains and joins everything that remains
    let fin = gw.shutdown();
    for (key, s) in &fin.sessions {
        assert_eq!(s.depth, 0, "{key} retired with phantom backlog");
    }
}

/// Deterministic closed-key accounting: once a key is hot-removed,
/// every subsequent fire at it is a loud [`ShedReason::Closed`] record
/// — and the other session keeps serving bit-exactly.
#[test]
fn fires_at_closed_keys_are_loud_closed_sheds() {
    let net = tiny_network(EVAL_N);
    let fmts = [Format::float(7, 6), Format::fixed(8, 8)];
    let store = Arc::new(WeightStore::with_budget(thrash_budget(&fmts)));
    let sched = QosScheduler::new(1);
    // a depth bound far above the offered load: the live session never
    // sheds, so the split is exactly closed-vs-served
    let slo = SloTarget::new(10_000.0, 64).unwrap();
    let delay = Duration::from_micros(50);

    let gw = Gateway::empty();
    let keys: Vec<SessionKey> = fmts
        .iter()
        .map(|&fmt| gw.adopt(slow_session(&net, fmt, slo, &store, &sched, delay)))
        .collect();
    warm_up(&gw, &keys).unwrap();
    gw.close(&keys[1]).expect("second session was hosted");

    let arrivals = ArrivalSchedule::parse("poisson:50000rps", 3).unwrap();
    let report = drive_open_loop(&gw, &keys, &arrivals, 40);

    assert_eq!(report.offered, 40);
    assert!(report.is_balanced());
    // request i -> keys[i % 2]: exactly half the stream hits the closed
    // key and every one of those is a typed Closed shed
    assert_eq!(report.served.len(), 20);
    assert_eq!(report.shed(), 20);
    assert_eq!(report.failed(), 0);
    for f in &report.failures {
        assert_eq!(f.key, keys[1]);
        match &f.kind {
            FailureKind::Shed(e) => assert_eq!(e.reason, ShedReason::Closed),
            other => panic!("expected a closed shed, got {other:?}"),
        }
    }
    assert!(report.served.iter().all(|(ki, _, _, _)| *ki == 0));
    assert_served_bit_identical(&report, &net, &fmts);

    gw.shutdown();
}
