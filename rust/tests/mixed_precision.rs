//! Mixed-precision plan acceptance (ISSUE 3) — tier-1, fixture-based,
//! no artifacts required.
//!
//! The load-bearing contract: a UNIFORM plan (`plan:*=<fmt>`, or an
//! explicit plan assigning one format everywhere) produces logits
//! bit-identical to the single-format path it spells out, through BOTH
//! the offline eval driver (`eval::forward_eval`) and a live serving
//! `Session` — uniform plans are the bit-exactness anchor that lets the
//! mixed-precision subsystem ride on the existing numerics contract
//! (DESIGN.md §Mixed precision).  Mixed plans are then exercised
//! through the same public surfaces: per-layer routing, session keys,
//! and the greedy `plan_search`.

use std::time::Duration;

use precis::eval::sweep::{forward_eval, EvalOptions};
use precis::formats::{Format, Plan, PrecisionSpec};
use precis::nn::Network;
use precis::numerics::{quantize_slice, Quantizer};
use precis::search::{plan_search, AccuracyModel, PlanSearchSpec};
use precis::serving::{Backend, BackendFactory, Gateway, NativeBackend, Session, SessionKey};
use precis::testing::fixtures::tiny_conv_network;

use std::sync::Arc;

fn native_factory(net: Arc<Network>) -> BackendFactory {
    Box::new(move || Ok(Box::new(NativeBackend::new(net)) as Box<dyn Backend>))
}

/// Acceptance: uniform plan ≡ single format through `forward_eval`,
/// across both representation kinds and a ragged batch split.
#[test]
fn uniform_plan_is_bit_identical_through_forward_eval() {
    let net = tiny_conv_network(10);
    let opts = EvalOptions { samples: 10, batch: 4 }; // 2.5 batches: ragged tail
    for fmt in [Format::float(7, 6), Format::fixed(8, 8), Format::SINGLE] {
        let (via_fmt, labels_a) =
            forward_eval(&mut NativeBackend::new(net.clone()), &fmt, &opts).unwrap();
        let (via_plan, labels_b) = forward_eval(
            &mut NativeBackend::new(net.clone()),
            Plan::uniform(fmt),
            &opts,
        )
        .unwrap();
        assert_eq!(labels_a, labels_b);
        assert_eq!(via_fmt.len(), via_plan.len());
        for i in 0..via_fmt.len() {
            assert_eq!(
                via_fmt[i].to_bits(),
                via_plan[i].to_bits(),
                "{fmt} logit {i}: {} vs {}",
                via_fmt[i],
                via_plan[i]
            );
        }
    }
}

/// Acceptance: uniform plan ≡ single format through a LIVE `Session`
/// (dynamic batching and all), for every response it serves.
#[test]
fn uniform_plan_session_is_bit_identical_to_single_format_session() {
    let net = tiny_conv_network(10);
    let fmt = Format::float(7, 6);
    let s_fmt = Session::with_factory(
        net.clone(),
        fmt,
        4,
        Duration::from_millis(3),
        native_factory(net.clone()),
    );
    let s_plan = Session::with_factory(
        net.clone(),
        Plan::uniform(fmt),
        4,
        Duration::from_millis(3),
        native_factory(net.clone()),
    );
    // distinct keys (a uniform plan is spelled differently)...
    assert_eq!(s_fmt.key().to_string(), "tiny-conv-fixture@float:m7e6");
    assert_eq!(s_plan.key().to_string(), "tiny-conv-fixture@plan:*=float:m7e6");

    // ...same function: every served logit row is bit-identical, and
    // both match the direct backend
    let x = net.eval_x.slice_rows(0, 10);
    let via_fmt = s_fmt.run_batch(&x).unwrap();
    let via_plan = s_plan.run_batch(&x).unwrap();
    let direct = NativeBackend::new(net.clone()).run_batch(&x, &fmt).unwrap();
    assert_eq!(via_fmt.shape(), via_plan.shape());
    for i in 0..via_fmt.data().len() {
        assert_eq!(via_fmt.data()[i].to_bits(), via_plan.data()[i].to_bits(), "logit {i}");
        assert_eq!(via_fmt.data()[i].to_bits(), direct.data()[i].to_bits(), "logit {i}");
    }
    assert_eq!(s_fmt.shutdown().requests, 10);
    assert_eq!(s_plan.shutdown().requests, 10);
}

/// A gateway hosts a mixed-precision session next to uniform ones,
/// keyed by the full plan spelling, with hot add/remove intact.
#[test]
fn gateway_hosts_mixed_plan_sessions_by_key() {
    let net = tiny_conv_network(8);
    let px: usize = net.input.iter().product();
    let gw = Gateway::empty();
    let uniform = gw.adopt(Session::with_factory(
        net.clone(),
        Format::float(7, 6),
        4,
        Duration::from_millis(3),
        native_factory(net.clone()),
    ));
    let plan = Plan::parse("plan:c1=float:m4e5,*=fixed:l8r8").unwrap();
    let mixed = gw.adopt(Session::with_factory(
        net.clone(),
        plan.clone(),
        4,
        Duration::from_millis(3),
        native_factory(net.clone()),
    ));
    assert_eq!(mixed.to_string(), format!("tiny-conv-fixture@{}", plan.id()));
    assert_eq!(gw.keys().len(), 2);

    // served responses match the direct backend under the same spec
    let pixels = net.eval_x.data()[..px].to_vec();
    let got = gw.infer(&mixed, pixels.clone()).unwrap();
    let want = NativeBackend::new(net.clone())
        .run_spec(&net.eval_x.slice_rows(0, 1), &PrecisionSpec::from(plan))
        .unwrap();
    assert_eq!(got.len(), net.classes);
    for i in 0..net.classes {
        assert_eq!(got[i].to_bits(), want.data()[i].to_bits(), "logit {i}");
    }
    // ...and differ from the uniform session's (the plan genuinely
    // changes the function)
    let got_uniform = gw.infer(&uniform, pixels).unwrap();
    assert_ne!(got, got_uniform);

    let closed = gw.close(&mixed).expect("mixed session was hosted");
    assert_eq!(closed.requests, 1);
    let stats = gw.shutdown();
    assert_eq!(stats.sessions.len(), 1);
}

/// Malformed and invalid plan session specs surface as clean errors
/// through the serving entry points (never panics) — including the
/// out-of-range `fixed:l100r100` regression through plan syntax.
#[test]
fn plan_session_specs_reject_bad_input_cleanly() {
    assert!(SessionKey::parse("net@plan:*=fixed:l100r100").is_err());
    assert!(SessionKey::parse("net@plan:c1=float:m99e9").is_err());
    assert!(SessionKey::parse("net@plan:").is_err());
    assert!(SessionKey::parse("net@plan:c1").is_err());
    // valid syntax round-trips through Display
    let k = SessionKey::parse("net@plan:c1=float:m4e5,*=fixed:l8r8").unwrap();
    assert_eq!(SessionKey::parse(&k.to_string()).unwrap(), k);
}

/// The split-pair forward factors exactly as specified (ISSUE 9):
/// weights staged on the WEIGHT half's grid, everything else — input
/// staging, the MAC chain, the bias add — on the ACTIVATION half's.
/// Reference: pre-quantize the network's weights onto the weight grid
/// by hand and run the activation half uniformly.  The weight grid is
/// chosen as a SUBSET of the activation grid (every `X(2,2)` value is
/// exactly representable in `F(10,6)`), so the uniform run's own weight
/// staging is a no-op on the pre-quantized values and the two paths
/// must agree bit-for-bit.
#[test]
fn split_pair_forward_composes_weight_and_activation_halves() {
    let net = tiny_conv_network(8);
    let x = net.eval_x.slice_rows(0, 8);
    let split = PrecisionSpec::parse("plan:*=w:fixed:l2r2+a:float:m10e6").unwrap();
    let got = NativeBackend::new(net.clone()).run_spec(&x, &split).unwrap();

    let wq = Quantizer::new(&Format::fixed(2, 2));
    let mut pre = (*net).clone();
    for name in net.quantized_layer_names() {
        let t = pre.weights.get_mut(&format!("{name}.w")).unwrap();
        quantize_slice(t.data_mut(), &wq);
    }
    let pre = Arc::new(pre);
    let uniform_a = PrecisionSpec::parse("plan:*=float:m10e6").unwrap();
    let want = NativeBackend::new(pre.clone()).run_spec(&x, &uniform_a).unwrap();

    assert_eq!(got.shape(), want.shape());
    for i in 0..got.data().len() {
        assert_eq!(
            got.data()[i].to_bits(),
            want.data()[i].to_bits(),
            "logit {i}: {} vs {}",
            got.data()[i],
            want.data()[i]
        );
    }

    // the pair is live on BOTH axes: neither uniform spelling matches
    let w_only = NativeBackend::new(net.clone())
        .run_spec(&x, &PrecisionSpec::parse("fixed:l2r2").unwrap())
        .unwrap();
    let a_only = NativeBackend::new(net.clone())
        .run_spec(&x, &PrecisionSpec::parse("float:m10e6").unwrap())
        .unwrap();
    assert_ne!(got.data(), w_only.data(), "activation half must be live");
    assert_ne!(got.data(), a_only.data(), "weight half must be live");
}

/// Split-pair session keys round-trip through the gateway exactly like
/// uniform plan keys: the `+` spelling IS the session identity.
#[test]
fn split_pair_session_keys_roundtrip_and_serve() {
    let net = tiny_conv_network(8);
    let plan = Plan::parse("plan:c1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6").unwrap();
    let session = Session::with_factory(
        net.clone(),
        plan.clone(),
        4,
        Duration::from_millis(3),
        native_factory(net.clone()),
    );
    let key = session.key().clone();
    assert_eq!(
        key.to_string(),
        "tiny-conv-fixture@plan:c1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6"
    );
    assert_eq!(SessionKey::parse(&key.to_string()).unwrap(), key);

    let x = net.eval_x.slice_rows(0, 4);
    let served = session.run_batch(&x).unwrap();
    let want = NativeBackend::new(net.clone())
        .run_spec(&x, &PrecisionSpec::from(plan))
        .unwrap();
    for i in 0..want.data().len() {
        assert_eq!(served.data()[i].to_bits(), want.data()[i].to_bits(), "logit {i}");
    }
    assert_eq!(session.shutdown().requests, 4);
}

/// `plan_search` end to end on the public API: the greedy search
/// returns a plan that meets the target after validating at most its
/// budget — orders of magnitude below exhaustive per-layer enumeration.
#[test]
fn plan_search_meets_target_with_few_validations() {
    let net = tiny_conv_network(16);
    let spec = PlanSearchSpec {
        ladder: vec![
            Format::SINGLE,
            Format::float(10, 6),
            Format::float(7, 6),
            Format::float(4, 5),
            Format::float(2, 3),
        ],
        target: 0.99,
        max_validations: 10,
        opts: EvalOptions { samples: 16, batch: 4 },
        seed: 2018,
    };
    let model = AccuracyModel { a: 1.0, b: 0.0, fit_r: 1.0, n_points: 0 };
    let out = plan_search(&net, &spec, &model).unwrap();
    assert!(out.measured_norm_acc >= spec.target);
    assert_eq!(out.exhaustive_plans, 625.0, "(5^2 axes)^2 layers of per-layer pairs");
    assert!((out.validations_spent as f64) < out.exhaustive_plans);
    // the chosen plan serves: open a session under it and check one
    // response against the offline eval path (the one-substrate rule)
    let session = Session::with_factory(
        net.clone(),
        out.plan.clone(),
        4,
        Duration::from_millis(3),
        native_factory(net.clone()),
    );
    let x = net.eval_x.slice_rows(0, 4);
    let served = session.run_batch(&x).unwrap();
    let (offline, _) = forward_eval(
        &mut NativeBackend::new(net.clone()),
        out.plan.clone(),
        &EvalOptions { samples: 4, batch: 4 },
    )
    .unwrap();
    for i in 0..offline.len() {
        assert_eq!(served.data()[i].to_bits(), offline[i].to_bits(), "logit {i}");
    }
}
