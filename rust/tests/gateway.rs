//! Gateway integration over the real artifacts: the ISSUE 2 acceptance
//! scenario.  One process serves two `(network, format)` sessions —
//! `lenet5@float:m7e6` and `alexnet-mini@fixed:l8r8` — under concurrent
//! clients, and the served logits are bit-identical to the offline
//! `eval` path for the same inputs (the one-substrate guarantee,
//! DESIGN.md §Serving).
//!
//! Like `tests/integration.rs`, every test skips with a stderr note
//! when `artifacts/` is absent (`PRECIS_REQUIRE_ARTIFACTS=1` promotes
//! the skip to a failure).  The artifact-independent session/gateway
//! contracts (init-failure propagation, drain-on-shutdown, routing)
//! are unit-tested in `src/serving/` against the fixture network and
//! run on every fresh clone.

use std::time::Duration;

use precis::eval::sweep::EvalOptions;
use precis::eval::{accuracy, forward_eval_parallel, topk_accuracy};
use precis::formats::Format;
use precis::nn::Zoo;
use precis::serving::{BackendKind, Gateway, SessionKey, SessionOptions};

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn zoo() -> Option<Zoo> {
    match Zoo::load(ARTIFACTS) {
        Ok(z) => Some(z),
        Err(e) => {
            if precis::testing::strict_env("PRECIS_REQUIRE_ARTIFACTS") {
                panic!("PRECIS_REQUIRE_ARTIFACTS is set but artifacts are unusable: {e:#}");
            }
            eprintln!("skipping: artifacts unusable at {ARTIFACTS}: {e:#} (run `make artifacts`)");
            None
        }
    }
}

/// The acceptance scenario: ≥2 concurrent sessions in one gateway, and
/// for every session the gateway's responses are bit-identical to the
/// logits `eval` computes offline — i.e. `eval::accuracy` and the
/// served traffic are the same function.
#[test]
fn gateway_serves_two_sessions_bit_identical_to_eval() {
    let Some(z) = zoo() else { return };
    let samples = 48usize;
    let gateway = Gateway::new(z, BackendKind::Native).with_options(SessionOptions {
        batch: 8,
        max_wait: Duration::from_millis(3),
        ..SessionOptions::default()
    });
    let k1 = gateway.open_spec("lenet5@float:m7e6").unwrap();
    let k2 = gateway.open_spec("alexnet-mini@fixed:l8r8").unwrap();
    assert_eq!(gateway.keys().len(), 2);

    // offline reference: the eval path (batch-parallel pool) on the
    // same inputs, plus the plain accuracy number
    let opts = EvalOptions { samples, batch: 32 };
    let mut reference = Vec::new();
    for key in [&k1, &k2] {
        let net = gateway.session(key).unwrap().network().clone();
        let (logits, labels) = forward_eval_parallel(&net, &key.spec, &opts, 4).unwrap();
        let eval_acc = accuracy(&net, &key.spec, samples).unwrap();
        reference.push((key.clone(), net, logits, labels, eval_acc));
    }

    // drive both sessions with concurrent closed-loop clients,
    // collecting the gateway's actual responses per session
    let mut served: Vec<Vec<(usize, Vec<f32>)>> =
        (0..reference.len()).map(|_| Vec::new()).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (kidx, (key, net, logits, _, _)) in reference.iter().enumerate() {
            for client in 0..3usize {
                let gateway = &gateway;
                let handle = scope.spawn(move || {
                    let px: usize = net.input.iter().product();
                    let mut rows = Vec::new();
                    let mut i = client;
                    while i < samples {
                        let pixels = net.eval_x.data()[i * px..(i + 1) * px].to_vec();
                        let got = gateway.infer(key, pixels).unwrap();
                        let want = &logits[i * net.classes..(i + 1) * net.classes];
                        for (j, (a, b)) in got.iter().zip(want).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{key} sample {i} logit {j}: served {a} vs eval {b}"
                            );
                        }
                        rows.push((i, got));
                        i += 3;
                    }
                    rows
                });
                handles.push((kidx, handle));
            }
        }
        for (kidx, handle) in handles {
            served[kidx].extend(handle.join().unwrap());
        }
    });

    // accuracy computed from the RESPONSES THE GATEWAY SERVED equals
    // eval::accuracy exactly (not merely the reference against itself)
    for (kidx, (key, net, _, labels, eval_acc)) in reference.iter().enumerate() {
        let mut rows = std::mem::take(&mut served[kidx]);
        rows.sort_by_key(|(i, _)| *i);
        assert_eq!(rows.len(), samples, "{key}: every sample served once");
        let served_logits: Vec<f32> =
            rows.iter().flat_map(|(_, l)| l.iter().copied()).collect();
        let served_acc = topk_accuracy(&served_logits, labels, net.classes, net.topk);
        assert_eq!(
            served_acc, *eval_acc,
            "{key}: served-path accuracy must equal eval::accuracy"
        );
    }

    let stats = gateway.shutdown();
    assert_eq!(stats.sessions.len(), 2);
    assert_eq!(stats.total_requests(), 2 * samples as u64);
    for (key, s) in &stats.sessions {
        assert_eq!(s.backend, "native", "{key}");
        assert!(s.batches >= samples as u64 / 8, "{key}: {s:?}");
        assert!(s.p99_queue_ms >= s.p50_queue_ms, "{key}: {s:?}");
    }
    // stats are keyed and sorted by session key
    let got: Vec<SessionKey> = stats.sessions.iter().map(|(k, _)| k.clone()).collect();
    let mut want = vec![k1, k2];
    want.sort();
    assert_eq!(got, want);
}

/// Hot add/remove while traffic flows: a sweep can be served live.
#[test]
fn gateway_hot_add_remove_under_traffic() {
    let Some(z) = zoo() else { return };
    let gateway = Gateway::new(z, BackendKind::Native).with_options(SessionOptions {
        batch: 4,
        max_wait: Duration::from_millis(2),
        ..SessionOptions::default()
    });
    let k1 = gateway.open("lenet5", Format::float(10, 6)).unwrap();
    let net = gateway.session(&k1).unwrap().network().clone();
    let px: usize = net.input.iter().product();
    let pixels = |i: usize| net.eval_x.data()[i * px..(i + 1) * px].to_vec();

    gateway.infer(&k1, pixels(0)).unwrap();

    // hot-add a second format of the same network mid-flight (the
    // sweep-served-live scenario), then a request to each
    let k2 = gateway.open("lenet5", Format::fixed(8, 8)).unwrap();
    gateway.infer(&k1, pixels(1)).unwrap();
    gateway.infer(&k2, pixels(1)).unwrap();

    // re-opening an existing key is idempotent
    let again = gateway.open("lenet5", Format::float(10, 6)).unwrap();
    assert_eq!(again, k1);
    assert_eq!(gateway.keys().len(), 2);

    // hot-remove the first: routing stops, the survivor still serves
    let closed = gateway.close(&k1).expect("k1 was hosted");
    assert_eq!(closed.requests, 2);
    assert!(gateway.infer(&k1, pixels(2)).is_err());
    gateway.infer(&k2, pixels(2)).unwrap();

    let stats = gateway.shutdown();
    assert_eq!(stats.sessions.len(), 1);
    assert_eq!(stats.sessions[0].0, k2);
    assert_eq!(stats.total_requests(), 2);
}

/// An unknown network in a session spec must surface as a clean error
/// (and an out-of-range format must not panic — the `Format::parse`
/// regression, exercised through the serving entry point).
#[test]
fn gateway_open_rejects_bad_specs() {
    let Some(z) = zoo() else { return };
    let gateway = Gateway::new(z, BackendKind::Native);
    assert!(gateway.open_spec("no-such-net@float:m7e6").is_err());
    assert!(gateway.open_spec("lenet5@fixed:l100r100").is_err());
    assert!(gateway.open_spec("lenet5").is_err());
    assert!(gateway.keys().is_empty());
    let _ = SessionKey::parse("lenet5@float:m7e6").unwrap();
}
