//! The `precis::obs` acceptance contract (ISSUE 10) — tier-1, fixture
//! based, no artifacts:
//!
//! * **Zero overhead when off, lock-free when on**: with profiling off
//!   and the metrics registry live, forwards are bit-identical to the
//!   plain pre-obs path and a concurrent warm phase acquires the store
//!   mutex ZERO times; the registry is a view over the store's own
//!   atomics, never a copy.
//! * **Profiled spans pin the router**: a profiled packed forward
//!   reports per-layer lanes exactly matching the packed router's
//!   assignments ([`QuantTable::resolve_for`] → `packed_labels`), with
//!   layer span times summing to at most the forward total.
//! * **Burn alerts reconcile with the books**: a driven overload
//!   (open-loop burst against a depth-gated slow session) emits at
//!   least one burn-rate [`Alert`](precis::obs::Event) whose shed and
//!   served counts equal the [`DriveReport`]'s records exactly, plus
//!   one structured shed event per driver-recorded shed.
//! * **The bench suite prices the obs hot paths**: `obs_overhead/*`
//!   sections and the `obs_profile_overhead/tiny-conv` ratio are in the
//!   `repro bench --json` report, and `bench_compare.py` documents the
//!   new section drift and the `packed_gap` track.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use anyhow::Result;

use precis::bench_harness::suite::run_suite;
use precis::bench_harness::{Bench, BenchReport};
use precis::formats::{Format, PrecisionSpec};
use precis::nn::{Network, QuantTable};
use precis::obs::{EventSink, Registry};
use precis::serving::{
    drive_open_loop, ArrivalSchedule, Backend, Gateway, NativeBackend, Session, SessionOptions,
    SloTarget,
};
use precis::store::WeightStore;
use precis::tensor::Tensor;
use precis::testing::fixtures::{tiny_conv_network, tiny_network};
use precis::util::json::Json;

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{ctx}: logit {i} ({} vs {})",
            got[i],
            want[i]
        );
    }
}

/// Acceptance (1): profiling off is bit-identical to the plain forward
/// path, and a concurrent warm phase with the registry live acquires
/// the store mutex zero times.  Profiling ON must not perturb the math
/// either — same bits, plus a profile.
#[test]
fn profiling_off_is_bit_identical_and_lockfree_with_the_registry_live() {
    let net = tiny_conv_network(4);
    let x = net.eval_x.slice_rows(0, 4);
    let spec = PrecisionSpec::parse("plan:c1=fixed:l8r8,fc=float:m7e6").unwrap();
    // the pre-obs reference: an uncached forward, profiler never touched
    let want = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)))
        .run_spec(&x, &spec)
        .unwrap();

    let store = Arc::new(WeightStore::unbounded());
    let registry = Registry::new();
    store.register_into(&registry);

    const SESSIONS: usize = 4;
    const WARM_FORWARDS: usize = 8;
    let warmed = Barrier::new(SESSIONS + 1);
    let measured = Barrier::new(SESSIONS + 1);
    let locks_when_warm = std::thread::scope(|s| {
        for t in 0..SESSIONS {
            let (net, store) = (net.clone(), store.clone());
            let (x, want, spec) = (&x, &want, &spec);
            let (warmed, measured) = (&warmed, &measured);
            s.spawn(move || {
                // profiling explicitly OFF: the obs build must behave
                // exactly like a build without the module
                let mut backend = NativeBackend::with_store(net, store).with_profiling(false);
                let cold = backend.run_spec(x, spec).unwrap();
                assert_bits_eq(cold.data(), want.data(), &format!("session {t} cold"));
                warmed.wait();
                measured.wait();
                for round in 0..WARM_FORWARDS {
                    let got = backend.run_spec(x, spec).unwrap();
                    assert_bits_eq(got.data(), want.data(), &format!("session {t} warm {round}"));
                }
            });
        }
        warmed.wait();
        let snapshot = store.lock_acquisitions();
        measured.wait();
        snapshot
    });
    assert_eq!(
        store.lock_acquisitions(),
        locks_when_warm,
        "warm forwards must stay mutex-free with the registry live"
    );

    // the registry reads the store's own atomics — identical books
    let s = store.stats();
    for (name, value) in [
        ("store/hits", s.hits),
        ("store/misses", s.misses),
        ("store/evictions", s.evictions),
        ("store/rejected", s.rejected),
        ("store/lock_acquisitions", store.lock_acquisitions()),
    ] {
        assert_eq!(registry.counter_value(name), Some(value), "{name}");
    }

    // profiling ON yields the same bits plus a profile; a plain backend
    // yields no profile at all
    let mut profiled = NativeBackend::with_store(net.clone(), store.clone()).with_profiling(true);
    let got = profiled.run_spec(&x, &spec).unwrap();
    assert_bits_eq(got.data(), want.data(), "profiled forward");
    let p = Backend::take_profile(&mut profiled).expect("profiling on records a profile");
    assert_eq!(p.batch, 4);
    let mut plain = NativeBackend::with_store(net, store);
    plain.run_spec(&x, &spec).unwrap();
    assert!(Backend::take_profile(&mut plain).is_none(), "profiling off records nothing");
}

/// Acceptance (2): a profiled packed forward's per-layer lanes are
/// exactly the packed router's assignments, over every router lane
/// (int16, int32, LUT, staged), and the layer spans sum to at most the
/// end-to-end forward time.
#[test]
fn profiled_spans_pin_the_packed_routers_lane_assignments() {
    let net = tiny_conv_network(8);
    let x = net.eval_x.slice_rows(0, 8);
    for spec_str in [
        "fixed:l3r3",                       // int16 lane
        "fixed:l4r4",                       // int32 lane
        "float:m7e6",                       // LUT lane
        "plan:c1=fixed:l3r3,fc=fixed:l8r8", // mixed int16 + LUT
        "float:m23e8",                      // identity: stays staged
    ] {
        let spec = PrecisionSpec::parse(spec_str).unwrap();
        let want: Vec<(String, String)> = QuantTable::resolve_for(&net, &spec, true)
            .unwrap()
            .packed_labels(&net)
            .into_iter()
            .map(|(n, l)| (n, l.to_string()))
            .collect();

        let mut backend = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()))
            .with_packed_exec(true)
            .with_profiling(true);
        backend.run_spec(&x, &spec).unwrap(); // cold: stages the weights
        backend.run_spec(&x, &spec).unwrap(); // warm: steady-state lanes
        let p = Backend::take_profile(&mut backend).expect("profiled forward records spans");

        let got: Vec<(String, String)> =
            p.layers.iter().map(|l| (l.name.clone(), l.lane.clone())).collect();
        assert_eq!(got, want, "{spec_str}: profiled lanes must match the router's assignments");
        assert_eq!(p.batch, 8, "{spec_str}");
        assert!(p.total_macs() > 0, "{spec_str}: GEMM layers issue MACs");
        assert!(p.total_s > 0.0 && p.layers_total_s() > 0.0, "{spec_str}: spans are timed");
        assert!(
            p.layers_total_s() <= p.total_s + 1e-6,
            "{spec_str}: layer spans ({}) cannot exceed the forward total ({})",
            p.layers_total_s(),
            p.total_s
        );
    }
}

/// A native backend slowed to `delay` per batch, so a burst arrival
/// schedule provably exceeds capacity and the depth gate must shed —
/// the same no-timing-luck idiom as `tests/qos_chaos.rs`.
struct SlowBackend {
    inner: NativeBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        self.inner.run_spec(x, spec)
    }
    fn network(&self) -> &Arc<Network> {
        self.inner.network()
    }
    fn label(&self) -> &'static str {
        "native"
    }
}

/// Acceptance (3): a driven overload emits at least one burn-rate
/// alert, and the alert's shed/served books reconcile EXACTLY with the
/// drive report's records — plus one structured shed event per
/// driver-recorded shed and a balanced session lifecycle.
#[test]
fn overload_drive_emits_burn_alerts_that_reconcile_with_the_books() {
    let net = tiny_network(8);
    let (sink, captured) = EventSink::capture();
    let sink = Arc::new(sink);
    let gw = Gateway::empty().with_events(sink.clone());

    // one depth-gated session at ~500 req/s capacity (2ms per
    // single-request batch); no warm-up, so the session's counters are
    // exactly the driver's books
    let n = net.clone();
    let opts = SessionOptions {
        batch: 1,
        max_wait: Duration::from_millis(0),
        slo: Some(SloTarget::new(10_000.0, 2).unwrap()), // depth-gated only
        ..SessionOptions::default()
    };
    let key = gw.adopt(Session::with_factory_qos(
        net.clone(),
        Format::fixed(8, 8),
        opts,
        None,
        Box::new(move || {
            let inner = NativeBackend::new(n);
            Ok(Box::new(SlowBackend { inner, delay: Duration::from_millis(2) }) as Box<dyn Backend>)
        }),
    ));

    // ~200 fires within a few ms against the 2ms/request service rate:
    // the depth bound (2) must shed most of the stream
    let arrivals = ArrivalSchedule::parse("burst:1000rps:50000rps:20ms:0.5", 2018).unwrap();
    let keys = [key.clone()];
    let report = drive_open_loop(&gw, &keys, &arrivals, 200);
    assert_eq!(report.offered, 200);
    assert!(
        report.is_balanced(),
        "served {} + shed {} + failed {} != offered {}",
        report.served.len(),
        report.shed(),
        report.failed(),
        report.offered
    );
    assert_eq!(report.failed(), 0);
    assert!(report.shed() > 0, "over-capacity open-loop drive must shed");

    // the stats path evaluates burn: a shed fraction this far above the
    // 1% error budget must alert, and the render surfaces it
    let stats = gw.stats();
    let (_, s) = stats.sessions.iter().find(|(k, _)| k == &key).expect("session listed");
    assert!(s.alerting, "burn {} over budget must alert (shed {})", s.burn, s.shed);
    assert!(s.burn >= 1.0, "slow-window burn must be over budget, got {}", s.burn);
    assert!(stats.render().contains('!'), "the burn column marks the alert:\n{}", stats.render());

    gw.shutdown();
    drop(sink); // last Arc: joins the writer, completing the capture

    let lines = captured.lines();
    let of_kind = |k: &str| -> Vec<&Json> {
        lines.iter().filter(|l| l.get("kind").and_then(Json::as_str) == Some(k)).collect()
    };
    assert_eq!(of_kind("session_open").len(), 1);
    assert_eq!(of_kind("session_close").len(), 1, "shutdown closes the session");
    assert_eq!(
        of_kind("shed").len() as u64,
        report.shed(),
        "one structured shed event per driver-recorded shed"
    );

    let alerts = of_kind("alert");
    assert!(!alerts.is_empty(), "a driven overload must emit at least one burn alert");
    let a = alerts[0];
    assert_eq!(a.get("key").and_then(Json::as_str), Some(key.to_string().as_str()));
    assert_eq!(
        a.get("shed").and_then(Json::as_f64),
        Some(report.shed() as f64),
        "the alert's shed count must reconcile with the drive report"
    );
    assert_eq!(
        a.get("served").and_then(Json::as_f64),
        Some(report.served.len() as f64),
        "the alert's served count must reconcile with the drive report"
    );
    assert!(a.get("fast").and_then(Json::as_f64).expect("fast burn") >= 1.0);
    assert!(a.get("slow").and_then(Json::as_f64).expect("slow burn") >= 1.0);
    // the alert was preceded by an ok -> burning transition
    let transitions = of_kind("slo_state");
    assert!(!transitions.is_empty(), "alerting must record a state transition");
    assert_eq!(transitions[0].get("to").and_then(Json::as_str), Some("burning"));
}

/// Acceptance (4): the bench suite prices the obs hot paths — the
/// `obs_overhead/*` sections and the `obs_profile_overhead/tiny-conv`
/// ratio are in the JSON report `repro bench --json` emits — and
/// `bench_compare.py`'s drift docstring documents both the new section
/// and the `packed_gap` track.
#[test]
fn bench_suite_prices_the_obs_hot_paths_and_the_comparator_documents_them() {
    let mut bench = Bench::quick();
    bench.warmup_iters = 1;
    bench.min_batches = 2;
    bench.min_time_s = 0.0;
    let mut report = BenchReport::new("obs-contract", "quick");
    run_suite(&mut bench, &mut report, 64, &[16], &[(10, 7, 9)], 4);

    let json = report.to_json().to_string();
    for name in [
        "obs_overhead/counter_add",
        "obs_overhead/histogram_record",
        "obs_overhead/forward_plain/batch4",
        "obs_overhead/forward_profiled/batch4",
    ] {
        assert!(json.contains(name), "bench json missing {name}");
    }
    let overhead = report.ratios.get("obs_profile_overhead/tiny-conv").copied();
    let overhead = overhead.expect("profiled-vs-plain forward ratio present");
    assert!(overhead.is_finite() && overhead > 0.0, "overhead ratio {overhead}");

    let compare = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../.github/scripts/bench_compare.py"
    ))
    .expect("bench_compare.py is readable from the repo");
    let docstring = compare.split("\"\"\"").nth(1).expect("module docstring");
    assert!(
        docstring.contains("obs_overhead"),
        "the comparator's drift docstring must note the obs section"
    );
    assert!(
        docstring.contains("packed_gap"),
        "the comparator's docstring must document the packed_gap track"
    );
}
