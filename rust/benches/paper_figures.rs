//! One bench per paper figure: times the end-to-end regeneration of each
//! figure's data series (in-repo harness; criterion unavailable offline).
//!
//! Figures that need accuracy sweeps are benched at reduced sample
//! counts/strides — the point is tracking the *cost* of each pipeline,
//! not regenerating publication data (use `repro figures` for that).
//!
//! `PRECIS_BENCH_JSON=path.json` writes the results as a
//! machine-readable `BENCH_*.json` report (`bench_compare.py` diffs
//! two; DESIGN.md §Perf).

use precis::bench_harness::{section, Bench, BenchReport};
use precis::coordinator::cache::ResultCache;
use precis::coordinator::Coordinator;
use precis::eval::sweep::EvalOptions;
use precis::figures;
use precis::formats::{self, Format};
use precis::nn::Zoo;
use precis::search::{collect_model_points, search, AccuracyModel, SearchSpec};

fn main() {
    let mut b = Bench::quick();

    section("fig4/fig5 (hardware model, analytic)");
    b.run("fig4_mac_delay_area", || figures::fig4().rows.len());
    b.run("fig5_speedup_composition", || figures::fig5().rows.len());

    let Ok(zoo) = Zoo::load(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")) else {
        println!("(artifacts/ missing — run `make artifacts` for the sweep benches)");
        save_json_if_requested(b);
        return;
    };
    let opts = EvalOptions { samples: 32, batch: 32 };

    section("fig6 (design-space sweep, 32 samples, stride 8)");
    {
        // ephemeral cache: we are timing the compute, not the cache
        let coord = Coordinator::new(zoo, ResultCache::ephemeral());
        b.run("fig6_lenet5/str8", || {
            figures::fig6(&coord, "lenet5", &opts, 8).unwrap().rows.len()
        });

        section("fig7 heatmap path (cached after first sweep)");
        b.run("fig7_lenet5_cached", || {
            figures::fig7(&coord, "lenet5", &opts).unwrap().rows.len()
        });

        section("fig8 (accumulation trace)");
        let net = coord.zoo.network("alexnet-mini").unwrap();
        b.run("fig8_alexnet_trace", || {
            figures::fig8(&net, 0).unwrap().rows.len()
        });

        section("fig9 (model points, lenet5 slice)");
        let lenet = coord.zoo.network("lenet5").unwrap();
        let space = formats::design_space(8);
        b.run("fig9_points_lenet5/str8", || {
            collect_model_points(&lenet, &space, &opts, 7).unwrap().len()
        });

        section("fig10/fig11 (model-driven search)");
        let pts: Vec<_> = collect_model_points(&lenet, &formats::design_space(4), &opts, 7)
            .unwrap()
            .into_iter()
            .map(|(_, p)| p)
            .collect();
        let model = AccuracyModel::fit(&pts);
        let cifar = coord.zoo.network("cifarnet").unwrap();
        let spec = SearchSpec {
            formats: (1..=18).map(|m| Format::float(m, 6)).collect(),
            target: 0.99,
            refine_samples: 2,
            opts,
            seed: 7,
        };
        b.run("search_cifarnet/float_ladder", || {
            search(&cifar, &spec, &model).unwrap().sample_forwards
        });
    }
    save_json_if_requested(b);
}

/// Honor `PRECIS_BENCH_JSON` like the hot_paths bench: dump everything
/// measured so far as a machine-readable report.  An empty report is
/// never written — `bench_compare.py` strictly rejects reports with no
/// results, so an empty file could only poison a comparison.
fn save_json_if_requested(b: Bench) {
    if let Ok(path) = std::env::var("PRECIS_BENCH_JSON") {
        let mut report = BenchReport::new("paper_figures", "quick");
        report.results = b.into_results();
        if report.results.is_empty() {
            println!("\n(nothing measured — not writing {path})");
            return;
        }
        report.save(std::path::Path::new(&path)).expect("write bench json");
        println!("\n(wrote {path})");
    }
}
