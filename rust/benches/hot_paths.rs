//! Micro-benchmarks of the hot paths (in-repo harness; criterion is not
//! in the offline crate set — DESIGN.md §6).  Run: `cargo bench`.
//!
//! Sections: quantizer kernels, quantized GEMM (blocked vs the retained
//! naive reference — the ISSUE 1 ≥2x acceptance gate), native forward
//! passes, PJRT batch execution (`pjrt` feature).  These are the
//! §Perf L3 measurement points — before/after numbers live in
//! CHANGES.md / EXPERIMENTS.md.

use precis::bench_harness::{section, Bench};
use precis::formats::{Format, PrecisionSpec};
use precis::nn::{gemm_q, gemm_q_naive, Zoo};
use precis::numerics::{dot_q, Quantizer};
use precis::serving::{Backend, NativeBackend};
use precis::util::rng::Pcg32;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Pcg32::seeded(seed);
    (0..n).map(|_| r.normal()).collect()
}

/// GEMM shapes of the seed networks' conv (im2col) and dense layers at
/// batch 32: (M, K, N) = (b*oh*ow, kh*kw*cin, cout) / (b, in, out).
const GEMM_SHAPES: [(usize, usize, usize); 4] = [
    (25088, 25, 20), // lenet5 conv1 at batch 32: 5x5x1 -> 20
    (32, 400, 120),  // lenet5 dense1 at batch 32: 400 -> 120
    (6272, 147, 24), // cifarnet conv1 at batch 32: 7x7x3 -> 24
    (3200, 432, 48), // alexnet-mini conv2 at batch 32: 3x3x48 -> 48
];

fn main() {
    let mut b = Bench::default();

    section("quantizer");
    let xs = randv(4096, 1);
    for fmt in [Format::float(7, 6), Format::SINGLE, Format::fixed(8, 8)] {
        let q = Quantizer::new(&fmt);
        let mut buf = xs.clone();
        let r = b.run(&format!("quantize_slice/4096/{}", fmt.id()), || {
            buf.copy_from_slice(&xs);
            precis::numerics::quantize_slice(&mut buf, &q);
            buf[0]
        });
        println!("    -> {:.0} Melem/s", r.throughput(4096.0) / 1e6);
    }

    section("dot_q (per-op-rounded MAC chain)");
    for k in [256usize, 1000] {
        let a = randv(k, 2);
        let w = randv(k, 3);
        for fmt in [Format::float(7, 6), Format::fixed(8, 8)] {
            let q = Quantizer::new(&fmt);
            let r = b.run(&format!("dot_q/K={k}/{}", fmt.id()), || dot_q(&a, &w, &q));
            println!("    -> {:.1} Mmac/s", r.throughput(k as f64) / 1e6);
        }
    }

    section("gemm_q: blocked kernel vs naive reference (seed-net shapes)");
    for (m, k, n) in GEMM_SHAPES {
        let a = randv(m * k, 4);
        let w = randv(k * n, 5);
        let mut out = vec![0.0f32; m * n];
        let macs = (m * k * n) as f64;
        for fmt in [Format::float(7, 6), Format::fixed(8, 8), Format::SINGLE] {
            let q = Quantizer::new(&fmt);
            let blocked = b.run(&format!("gemm_q/{m}x{k}x{n}/{}", fmt.id()), || {
                gemm_q(&a, &w, &mut out, m, k, n, &q);
                out[0]
            });
            let naive = b.run(&format!("gemm_q_naive/{m}x{k}x{n}/{}", fmt.id()), || {
                gemm_q_naive(&a, &w, &mut out, m, k, n, &q);
                out[0]
            });
            println!(
                "    -> blocked {:.1} Mmac/s, naive {:.1} Mmac/s: {:.2}x",
                blocked.throughput(macs) / 1e6,
                naive.throughput(macs) / 1e6,
                naive.median / blocked.median
            );
        }
    }

    // artifact-dependent benches are skipped gracefully when absent
    let Ok(zoo) = Zoo::load(ARTIFACTS) else {
        println!("\n(artifacts/ missing — run `make artifacts` for the network benches)");
        return;
    };

    section("native forward via serving::Backend (batch 32)");
    for name in ["lenet5", "cifarnet", "alexnet-mini", "vgg-mini", "googlenet-mini"] {
        let net = zoo.network(name).unwrap();
        let mut backend = NativeBackend::new(net.clone());
        let x = net.eval_x.slice_rows(0, 32);
        let fmt = Format::float(7, 6);
        let r = b.run(&format!("forward/{name}/batch32"), || {
            backend.run_batch(&x, &fmt).unwrap().data()[0]
        });
        println!("    -> {:.1} samples/s", r.throughput(32.0));
    }

    // per-layer plans ride the same engine through a memoized quantizer
    // table: the mixed-plan forward must cost the same as uniform
    section("mixed-precision plan forward (first layer fixed:l8r8, rest float:m7e6)");
    for name in ["lenet5", "alexnet-mini"] {
        let net = zoo.network(name).unwrap();
        let first = net.quantized_layer_names()[0].clone();
        let spec =
            PrecisionSpec::parse(&format!("plan:{first}=fixed:l8r8,*=float:m7e6")).unwrap();
        let mut backend = NativeBackend::new(net.clone());
        let x = net.eval_x.slice_rows(0, 32);
        let r = b.run(&format!("forward_plan/{name}/batch32"), || {
            backend.run_spec(&x, &spec).unwrap().data()[0]
        });
        println!("    -> {:.1} samples/s", r.throughput(32.0));
    }

    pjrt_bench(&mut b, &zoo);
}

#[cfg(feature = "pjrt")]
fn pjrt_bench(b: &mut Bench, zoo: &Zoo) {
    use precis::runtime::Runtime;

    section("PJRT batch execution (lenet5)");
    match Runtime::cpu() {
        Ok(rt) => {
            let net = zoo.network("lenet5").unwrap();
            let model = rt
                .load_network(&net, &zoo.dir, "float", zoo.batch)
                .expect("load artifact");
            let x = net.eval_x.slice_rows(0, zoo.batch);
            let fmt = Format::float(7, 6);
            let r = b.run("pjrt_run_batch/lenet5/batch32", || {
                model.run_batch(&x, &fmt).unwrap().data()[0]
            });
            println!("    -> {:.1} samples/s", r.throughput(32.0));
        }
        Err(e) => println!("(PJRT unavailable: {e})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_bench(_b: &mut Bench, _zoo: &Zoo) {
    println!("\n(PJRT bench skipped: build with --features pjrt — DESIGN.md §5)");
}
