//! Micro-benchmarks of the hot paths (in-repo harness; criterion is not
//! in the offline crate set).  Run: `cargo bench --offline`.
//!
//! Sections: quantizer kernels, quantized GEMM, native forward passes,
//! PJRT batch execution.  These are the §Perf L3 measurement points —
//! before/after numbers live in EXPERIMENTS.md.

use precis::bench_harness::{section, Bench};
use precis::formats::Format;
use precis::nn::{Engine, Zoo};
use precis::numerics::{dot_q, Quantizer};
use precis::runtime::Runtime;
use precis::util::rng::Pcg32;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Pcg32::seeded(seed);
    (0..n).map(|_| r.normal()).collect()
}

fn main() {
    let mut b = Bench::default();

    section("quantizer");
    let xs = randv(4096, 1);
    for fmt in [Format::float(7, 6), Format::SINGLE, Format::fixed(8, 8)] {
        let q = Quantizer::new(&fmt);
        let mut buf = xs.clone();
        let r = b.run(&format!("quantize_slice/4096/{}", fmt.id()), || {
            buf.copy_from_slice(&xs);
            precis::numerics::quantize_slice(&mut buf, &q);
            buf[0]
        });
        println!(
            "    -> {:.0} Melem/s",
            r.throughput(4096.0) / 1e6
        );
    }

    section("dot_q (per-op-rounded MAC chain)");
    for k in [256usize, 1000] {
        let a = randv(k, 2);
        let w = randv(k, 3);
        for fmt in [Format::float(7, 6), Format::fixed(8, 8)] {
            let q = Quantizer::new(&fmt);
            let r = b.run(&format!("dot_q/K={k}/{}", fmt.id()), || dot_q(&a, &w, &q));
            println!("    -> {:.1} Mmac/s", r.throughput(k as f64) / 1e6);
        }
    }

    section("gemm_q");
    for (m, k, n) in [(64usize, 256usize, 32usize), (400, 147, 24), (100, 600, 32)] {
        let a = randv(m * k, 4);
        let w = randv(k * n, 5);
        let mut out = vec![0.0f32; m * n];
        let q = Quantizer::new(&Format::float(7, 6));
        let r = b.run(&format!("gemm_q/{m}x{k}x{n}/float:m7e6"), || {
            precis::nn::gemm_q(&a, &w, &mut out, m, k, n, &q);
            out[0]
        });
        println!(
            "    -> {:.1} Mmac/s",
            r.throughput((m * k * n) as f64) / 1e6
        );
    }

    // artifact-dependent benches are skipped gracefully when absent
    let Ok(zoo) = Zoo::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) else {
        println!("\n(artifacts/ missing — run `make artifacts` for the network benches)");
        return;
    };

    section("native forward (batch 32)");
    let mut engine = Engine::new();
    for name in ["lenet5", "cifarnet", "alexnet-mini", "vgg-mini", "googlenet-mini"] {
        let net = zoo.network(name).unwrap();
        let x = net.eval_x.slice_rows(0, 32);
        let fmt = Format::float(7, 6);
        let r = b.run(&format!("forward/{name}/batch32"), || {
            engine.forward(&net, &x, &fmt).data()[0]
        });
        println!("    -> {:.1} samples/s", r.throughput(32.0));
    }

    section("PJRT batch execution (lenet5)");
    match Runtime::cpu() {
        Ok(rt) => {
            let net = zoo.network("lenet5").unwrap();
            let model = rt
                .load_network(&net, &zoo.dir, "float", zoo.batch)
                .expect("load artifact");
            let x = net.eval_x.slice_rows(0, zoo.batch);
            let fmt = Format::float(7, 6);
            let r = b.run("pjrt_run_batch/lenet5/batch32", || {
                model.run_batch(&x, &fmt).unwrap().data()[0]
            });
            println!("    -> {:.1} samples/s", r.throughput(32.0));
        }
        Err(e) => println!("(PJRT unavailable: {e})"),
    }
}
