//! Micro-benchmarks of the hot paths (in-repo harness; criterion is not
//! in the offline crate set — DESIGN.md §6).  Run: `cargo bench`.
//!
//! The headless sections (quantizer kernels, monomorphized-vs-scalar
//! `q_slice`, blocked-vs-naive quantized GEMM, fixture forward with a
//! mixed per-layer plan, warm-store cached-vs-restaged forward, and
//! the packed weight codec) are the shared `bench_harness::suite` — the
//! exact suite `repro bench --json` runs for the perf-regression
//! pipeline, so this bench and the `BENCH_*.json` trajectory can never
//! measure different code.  Artifact-dependent sections (zoo forward
//! passes, PJRT batch execution) follow and skip gracefully without
//! `artifacts/`.  These are the §Perf L3 measurement points.
//!
//! Env knobs: `PRECIS_BENCH_QUICK=1` runs the quick preset;
//! `PRECIS_BENCH_JSON=path.json` additionally writes the headless
//! suite's machine-readable report.

use precis::bench_harness::{section, suite, Bench};
use precis::formats::{Format, PrecisionSpec};
use precis::nn::Zoo;
use precis::serving::{Backend, NativeBackend};

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn main() {
    let quick = std::env::var("PRECIS_BENCH_QUICK").is_ok();
    let report = suite::hot_paths_report("hot_paths", quick);
    if let Ok(path) = std::env::var("PRECIS_BENCH_JSON") {
        report.save(std::path::Path::new(&path)).expect("write bench json");
        println!("\n(wrote {path})");
    }

    // artifact-dependent benches are skipped gracefully when absent
    let Ok(zoo) = Zoo::load(ARTIFACTS) else {
        println!("\n(artifacts/ missing — run `make artifacts` for the network benches)");
        return;
    };
    let mut b = if quick { Bench::quick() } else { Bench::default() };

    section("native forward via serving::Backend (batch 32)");
    for name in ["lenet5", "cifarnet", "alexnet-mini", "vgg-mini", "googlenet-mini"] {
        let net = zoo.network(name).unwrap();
        let mut backend = NativeBackend::new(net.clone());
        let x = net.eval_x.slice_rows(0, 32);
        let fmt = Format::float(7, 6);
        let r = b.run(&format!("forward/{name}/batch32"), || {
            backend.run_batch(&x, &fmt).unwrap().data()[0]
        });
        println!("    -> {:.1} samples/s", r.throughput(32.0));
    }

    // per-layer plans ride the same engine through a memoized quantizer
    // table: the mixed-plan forward must cost the same as uniform
    section("mixed-precision plan forward (first layer fixed:l8r8, rest float:m7e6)");
    for name in ["lenet5", "alexnet-mini"] {
        let net = zoo.network(name).unwrap();
        let first = net.quantized_layer_names()[0].clone();
        let spec =
            PrecisionSpec::parse(&format!("plan:{first}=fixed:l8r8,*=float:m7e6")).unwrap();
        let mut backend = NativeBackend::new(net.clone());
        let x = net.eval_x.slice_rows(0, 32);
        let r = b.run(&format!("forward_plan/{name}/batch32"), || {
            backend.run_spec(&x, &spec).unwrap().data()[0]
        });
        println!("    -> {:.1} samples/s", r.throughput(32.0));
    }

    pjrt_bench(&mut b, &zoo);
}

#[cfg(feature = "pjrt")]
fn pjrt_bench(b: &mut Bench, zoo: &Zoo) {
    use precis::runtime::Runtime;

    section("PJRT batch execution (lenet5)");
    match Runtime::cpu() {
        Ok(rt) => {
            let net = zoo.network("lenet5").unwrap();
            let model = rt
                .load_network(&net, &zoo.dir, "float", zoo.batch)
                .expect("load artifact");
            let x = net.eval_x.slice_rows(0, zoo.batch);
            let fmt = Format::float(7, 6);
            let r = b.run("pjrt_run_batch/lenet5/batch32", || {
                model.run_batch(&x, &fmt).unwrap().data()[0]
            });
            println!("    -> {:.1} samples/s", r.throughput(32.0));
        }
        Err(e) => println!("(PJRT unavailable: {e})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_bench(_b: &mut Bench, _zoo: &Zoo) {
    println!("\n(PJRT bench skipped: build with --features pjrt — DESIGN.md §5)");
}
