//! PER-LAYER MIXED-PRECISION DRIVER — the greedy plan search over the
//! whole model zoo (DESIGN.md §Mixed precision):
//!
//! 1. loads the AOT-trained model zoo (`make artifacts`);
//! 2. cross-validates the §3.3 accuracy model per network (fit on the
//!    other reference networks, never on the network under search);
//! 3. runs `search::plan_search` — start uniform-wide, narrow one layer
//!    at a time ranked by probe-R², validate only the survivors — and
//!    compares the resulting per-layer plan against the uniform format
//!    the single-format search would pick;
//! 4. reports predicted vs measured accuracy, the MAC-weighted hardware
//!    speedup of each plan, and the search cost against exhaustive
//!    per-layer enumeration (`ladder^layers` plans).
//!
//!     cargo run --release --example plan_search [-- --samples 128]

use anyhow::Result;

use precis::coordinator::cache::ResultCache;
use precis::coordinator::Coordinator;
use precis::eval::sweep::EvalOptions;
use precis::figures::cross_validated_model;
use precis::nn::Zoo;
use precis::search::{plan_search, PlanSearchSpec};
use precis::util::cli::Args;
use precis::util::timer::Timer;

/// Repo-root artifacts/results dirs, valid from any cwd (matches
/// tests/benches).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
const CACHE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../results/cache.json");

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let samples = args.get_usize("samples", 128)?;
    let seed = args.get_usize("seed", 2018)? as u64;
    let target = args.get_f64("target", 0.99)?;
    let opts = EvalOptions { samples, batch: 32 };

    let t_total = Timer::start();
    let zoo = Zoo::load(ARTIFACTS)?;
    let cache = ResultCache::open(CACHE);
    let coord = Coordinator::new(zoo, cache);

    println!(
        "{:<16} {:>9} {:>9} {:>10} {:>12} {:>14}",
        "network", "speedup", "pred_na", "meas_na", "validations", "vs_exhaustive"
    );

    for net in coord.zoo.by_size_desc() {
        let t = Timer::start();
        let model = cross_validated_model(&coord, &net.name, &opts, seed)?;
        let spec = PlanSearchSpec { target, opts, seed, ..Default::default() };
        let out = plan_search(&net, &spec, &model)?;
        println!(
            "{:<16} {:>8.2}x {:>9.4} {:>10.4} {:>12} {:>13.0}x  ({:.0}s)",
            net.name,
            out.speedup,
            out.predicted_norm_acc,
            out.measured_norm_acc,
            out.validations_spent,
            out.exhaustive_plans / out.validations_spent.max(1) as f64,
            t.elapsed_s(),
        );
        println!("    plan: {}", out.plan.id());
    }
    coord.cache.flush()?;
    println!("\ntotal wall-clock: {:.0}s", t_total.elapsed_s());
    Ok(())
}
