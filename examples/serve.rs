//! Serving-style driver: the multi-model, multi-format gateway under a
//! closed-loop client population or an open-loop arrival trace,
//! reporting per-session latency percentiles, accuracy, throughput,
//! batching efficiency and shed accounting.
//!
//! One process hosts N `(network, format)` sessions simultaneously —
//! by default `lenet5@float:m7e6` and `alexnet-mini@fixed:l8r8` — and
//! routes every request by session key.  With the `pjrt` feature (and
//! a real `xla` crate — DESIGN.md §5) the sessions execute the
//! AOT/PJRT artifacts; otherwise they fall back cleanly to the native
//! engine, which is bit-exact by contract (DESIGN.md §3).
//!
//!     cargo run --release --example serve -- \
//!         [--sessions lenet5@float:m7e6,alexnet-mini@fixed:l8r8] \
//!         [--requests 256] [--clients 8] [--wait-ms 5] \
//!         [--backend auto|native|pjrt] [--weight-budget 8m] \
//!         [--arrivals poisson:200rps] [--slo 20ms:256] [--seed 2018] \
//!         [--events-out events.jsonl]

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use precis::eval::topk_accuracy;
use precis::nn::Zoo;
use precis::serving::{
    drive_open_loop, split_session_specs, warm_up, ArrivalSchedule, BackendKind, ClosedLoop,
    Gateway, SessionKey, SessionOptions, SloTarget,
};
use precis::util::cli::Args;

/// Repo-root artifacts dir, valid from any cwd (matches tests/benches).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let specs = args
        .get_or("sessions", "lenet5@float:m7e6,alexnet-mini@fixed:l8r8")
        .to_string();
    let n_requests = args.get_usize("requests", 256)?;
    let n_clients = args.get_usize("clients", 8)?.max(1);
    let wait_ms = args.get_usize("wait-ms", 5)?;
    let seed = args.get_usize("seed", 2018)? as u64;
    let kind = BackendKind::parse(args.get_or("backend", "auto"))?;
    // gateway-wide pre-quantized weight-store budget (DESIGN.md §Storage)
    let weight_budget = args
        .get("weight-budget")
        .map(precis::store::parse_byte_size)
        .transpose()?;
    // QoS: SLO-gated admission + open-loop arrivals (DESIGN.md §Serving QoS)
    let slo = args.get("slo").map(SloTarget::parse).transpose()?;
    let arrivals = args
        .get("arrivals")
        .map(|s| ArrivalSchedule::parse(s, seed))
        .transpose()?;

    // structured event log (session lifecycle, sheds, store evictions,
    // SLO burn alerts) — DESIGN.md §Observability
    let events_path = args.get("events-out").map(|s| s.to_string());
    let events = events_path
        .as_deref()
        .map(|p| precis::obs::EventSink::to_file(std::path::Path::new(p)).map(Arc::new))
        .transpose()?;

    let zoo = Zoo::load(ARTIFACTS)?;
    let batch = zoo.batch;
    let mut gateway = Gateway::new(zoo, kind).with_options(SessionOptions {
        batch: 0, // the artifact batch size
        max_wait: Duration::from_millis(wait_ms as u64),
        weight_budget,
        slo,
        ..SessionOptions::default()
    });
    if let Some(sink) = &events {
        gateway = gateway.with_events(sink.clone());
    }
    let keys: Vec<SessionKey> = split_session_specs(&specs)
        .iter()
        .map(|s| gateway.open_spec(s))
        .collect::<Result<_>>()?;

    let mode = match &arrivals {
        Some(sched) => format!("open-loop {sched}"),
        None => format!("{n_clients} closed-loop clients"),
    };
    println!(
        "gateway: {} concurrent session(s) in one process (batch {batch}, backend {}, \
         {mode}, {n_requests} requests round-robined by key)",
        keys.len(),
        kind.as_str()
    );

    // One warm-up request per session before measurement (proves each
    // backend end to end, absorbs cold-start symmetrically), then the
    // shared drivers — the same ones `repro serve` uses.
    warm_up(&gateway, &keys)?;

    let report = match &arrivals {
        Some(sched) => drive_open_loop(&gateway, &keys, sched, n_requests),
        None => ClosedLoop::new(n_clients).drive(&gateway, &keys, n_requests),
    };

    // the shared per-key offered/served/shed table, then the live
    // telemetry snapshot while the gateway still serves — stats are
    // not a shutdown-only artifact
    println!("\n{}", report.render(&keys));
    println!("{}", gateway.stats().render());
    println!(
        "throughput: {:.1} served/s aggregate ({:.2}s wall)\n",
        report.served.len() as f64 / report.wall_s.max(1e-9),
        report.wall_s
    );

    // per-session accuracy of the actually-served responses (sheds
    // refuse work; they never perturb what IS served)
    for (ki, key) in keys.iter().enumerate() {
        let net: Arc<_> = gateway.session(key).unwrap().network().clone();
        let mut rows: Vec<(usize, &[f32])> = Vec::new();
        for (k, sample, _, logits) in &report.served {
            if k == &ki {
                rows.push((*sample, logits.as_slice()));
            }
        }
        let logits: Vec<f32> = rows.iter().flat_map(|(_, l)| l.iter().copied()).collect();
        let labels: Vec<i32> = rows.iter().map(|(s, _)| net.eval_y[*s]).collect();
        let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
        println!(
            "{:<32} {} served  top-{} acc {:.4}",
            key.to_string(),
            rows.len(),
            net.topk,
            acc
        );
    }
    assert!(report.is_balanced(), "drive accounting is unbalanced");

    let stats = gateway.shutdown();
    println!(
        "\nshutdown: {} requests in {} batches across {} session(s)",
        stats.total_requests(),
        stats.total_batches(),
        stats.sessions.len()
    );
    // dropping the last sink Arc joins the writer thread, so the log
    // file is complete before we report it
    if let (Some(sink), Some(path)) = (events, events_path) {
        let (emitted, dropped) = (sink.emitted(), sink.dropped());
        drop(sink);
        println!("events: {emitted} emitted ({dropped} dropped) -> {path}");
    }
    Ok(())
}
