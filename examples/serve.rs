//! Serving-style driver: the dynamic-batching inference server under a
//! closed-loop client population, reporting latency percentiles,
//! throughput and batching efficiency.
//!
//! With the `pjrt` feature (and a real `xla` crate — DESIGN.md §5) the
//! backend is the AOT/PJRT executable; otherwise it falls back cleanly
//! to the native engine, which is bit-exact by contract (DESIGN.md §3).
//!
//!     cargo run --release --example serve -- [--net lenet5] \
//!         [--format float:m10e6] [--requests 256] [--clients 8] \
//!         [--backend auto|native|pjrt]

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use precis::coordinator::server::InferenceServer;
use precis::eval::topk_accuracy;
use precis::formats::Format;
use precis::nn::{Network, Zoo};
use precis::util::cli::Args;

/// Repo-root artifacts dir, valid from any cwd (matches tests/benches).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

/// Spawn the PJRT-backed server, or `Err` when this build has no PJRT
/// runtime or the artifact is missing.  PJRT handles are not Send, so
/// the one-and-only client is built on the dispatcher thread via the
/// factory; runtime startup failures surface on the caller's warm-up
/// request (below), never as a second probe client.
#[cfg(feature = "pjrt")]
fn spawn_pjrt(
    net: Arc<Network>,
    dir: PathBuf,
    kind: String,
    batch: usize,
    fmt: Format,
    wait: Duration,
) -> Result<InferenceServer> {
    use precis::coordinator::server::PjrtRunner;
    use precis::runtime::Runtime;
    let hlo = net.hlo_path(&dir, &kind)?;
    anyhow::ensure!(hlo.exists(), "missing HLO artifact {}", hlo.display());
    let net2 = net.clone();
    Ok(InferenceServer::spawn(net, batch, fmt, wait, move || {
        let rt = Runtime::cpu()?;
        let model = rt.load_network(&net2, &dir, &kind, batch)?;
        Ok(PjrtRunner { model })
    }))
}

#[cfg(not(feature = "pjrt"))]
fn spawn_pjrt(
    _net: Arc<Network>,
    _dir: PathBuf,
    _kind: String,
    _batch: usize,
    _fmt: Format,
    _wait: Duration,
) -> Result<InferenceServer> {
    anyhow::bail!("this build has no PJRT runtime (rebuild with `--features pjrt` — DESIGN.md §5)")
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let net_name = args.get_or("net", "lenet5").to_string();
    let fmt = Format::parse(args.get_or("format", "float:m10e6"))?;
    let n_requests = args.get_usize("requests", 256)?;
    let n_clients = args.get_usize("clients", 8)?;
    let wait_ms = args.get_usize("wait-ms", 10)?;
    let backend = args.get_or("backend", "auto").to_string();

    let zoo = Zoo::load(ARTIFACTS)?;
    let net = zoo.network(&net_name)?;
    let batch = zoo.batch;
    let dir = zoo.dir.clone();
    let kind = if fmt.is_float() { "float" } else { "fixed" };
    let wait = Duration::from_millis(wait_ms as u64);

    println!(
        "serving {net_name} @ {} (batch {batch}, {n_clients} closed-loop clients, \
         {n_requests} requests, backend {backend})",
        fmt.id()
    );

    let px: usize = net.input.iter().product();
    // Every backend gets one warm-up request before measurement: it
    // proves the backend end to end (the PJRT client + compile happen
    // lazily on the dispatcher thread) and absorbs cold-start latency
    // symmetrically, so native and pjrt telemetry stay comparable —
    // each includes exactly one artificial 1-request warm-up batch.
    let warm_up = |s: InferenceServer| -> Result<InferenceServer> {
        s.infer(net.eval_x.data()[..px].to_vec())?;
        Ok(s)
    };
    // `resolved` records which backend actually serves, so the stdout
    // report can never label auto-fallback native numbers as pjrt
    let (server, resolved) = match backend.as_str() {
        "native" => (warm_up(InferenceServer::native(net.clone(), batch, fmt, wait))?, "native"),
        // explicit pjrt: unavailability is a hard error, never a silent
        // native run mislabeled as pjrt
        "pjrt" => (
            warm_up(spawn_pjrt(net.clone(), dir, kind.to_string(), batch, fmt, wait)?)?,
            "pjrt",
        ),
        "auto" => {
            match spawn_pjrt(net.clone(), dir, kind.to_string(), batch, fmt, wait)
                .and_then(&warm_up)
            {
                Ok(s) => (s, "pjrt"),
                Err(e) => {
                    eprintln!("(PJRT unavailable — serving on the native engine: {e:#})");
                    (
                        warm_up(InferenceServer::native(net.clone(), batch, fmt, wait))?,
                        "native",
                    )
                }
            }
        }
        b => anyhow::bail!("unknown backend {b:?} (auto|native|pjrt)"),
    };
    let server = Arc::new(server);
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(n_requests);
    let mut predictions: Vec<(usize, Vec<f32>)> = Vec::with_capacity(n_requests);

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for cid in 0..n_clients {
            let server = server.clone();
            let net = net.clone();
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                let mut i = cid;
                while i < n_requests {
                    let sample = i % net.eval_len();
                    let pixels = net.eval_x.data()[sample * px..(sample + 1) * px].to_vec();
                    let t = Instant::now();
                    let logits = server.infer(pixels).expect("inference failed");
                    out.push((i, t.elapsed().as_secs_f64(), logits));
                    i += n_clients;
                }
                out
            }));
        }
        for h in handles {
            for (i, lat, logits) in h.join().unwrap() {
                latencies.push(lat);
                predictions.push((i, logits));
            }
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    // accuracy over the served responses
    predictions.sort_by_key(|(i, _)| *i);
    let classes = net.classes;
    let logits: Vec<f32> = predictions.iter().flat_map(|(_, l)| l.iter().copied()).collect();
    let labels: Vec<i32> = (0..n_requests).map(|i| net.eval_y[i % net.eval_len()]).collect();
    let acc = topk_accuracy(&logits, &labels, classes, net.topk);

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize] * 1e3;
    let stats = Arc::try_unwrap(server)
        .map(|s| s.shutdown())
        .unwrap_or_default();

    println!("\nresults (backend {resolved}):");
    println!("  throughput     : {:.1} req/s", n_requests as f64 / wall);
    println!("  latency p50    : {:.2} ms", pct(0.5));
    println!("  latency p90    : {:.2} ms", pct(0.9));
    println!("  latency p99    : {:.2} ms", pct(0.99));
    println!("  top-{} accuracy : {:.4}", net.topk, acc);
    println!(
        "  batches        : {} ({:.1} req/batch, {:.1}% padded slots)",
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        100.0 * stats.padded_slots as f64 / (stats.batches.max(1) * batch as u64) as f64
    );
    Ok(())
}
