//! Serving-style driver: the multi-model, multi-format gateway under a
//! closed-loop client population, reporting per-session latency
//! percentiles, accuracy, throughput and batching efficiency.
//!
//! One process hosts N `(network, format)` sessions simultaneously —
//! by default `lenet5@float:m7e6` and `alexnet-mini@fixed:l8r8` — and
//! routes every request by session key.  With the `pjrt` feature (and
//! a real `xla` crate — DESIGN.md §5) the sessions execute the
//! AOT/PJRT artifacts; otherwise they fall back cleanly to the native
//! engine, which is bit-exact by contract (DESIGN.md §3).
//!
//!     cargo run --release --example serve -- \
//!         [--sessions lenet5@float:m7e6,alexnet-mini@fixed:l8r8] \
//!         [--requests 256] [--clients 8] [--wait-ms 5] \
//!         [--backend auto|native|pjrt] [--weight-budget 8m]

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use precis::eval::topk_accuracy;
use precis::nn::Zoo;
use precis::serving::{
    drive_closed_loop, split_session_specs, warm_up, BackendKind, Gateway, SessionKey,
    SessionOptions,
};
use precis::util::cli::Args;

/// Repo-root artifacts dir, valid from any cwd (matches tests/benches).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let specs = args
        .get_or("sessions", "lenet5@float:m7e6,alexnet-mini@fixed:l8r8")
        .to_string();
    let n_requests = args.get_usize("requests", 256)?;
    let n_clients = args.get_usize("clients", 8)?.max(1);
    let wait_ms = args.get_usize("wait-ms", 5)?;
    let kind = BackendKind::parse(args.get_or("backend", "auto"))?;
    // gateway-wide pre-quantized weight-store budget (DESIGN.md §Storage)
    let weight_budget = args
        .get("weight-budget")
        .map(precis::store::parse_byte_size)
        .transpose()?;

    let zoo = Zoo::load(ARTIFACTS)?;
    let batch = zoo.batch;
    let gateway = Gateway::new(zoo, kind).with_options(SessionOptions {
        batch: 0, // the artifact batch size
        max_wait: Duration::from_millis(wait_ms as u64),
        weight_budget,
    });
    let keys: Vec<SessionKey> = split_session_specs(&specs)
        .iter()
        .map(|s| gateway.open_spec(s))
        .collect::<Result<_>>()?;

    println!(
        "gateway: {} concurrent session(s) in one process (batch {batch}, backend {}, \
         {n_clients} closed-loop clients, {n_requests} requests round-robined by key)",
        keys.len(),
        kind.as_str()
    );

    // One warm-up request per session before measurement (proves each
    // backend end to end, absorbs cold-start symmetrically), then the
    // shared closed-loop driver — the same one `repro serve` uses.
    warm_up(&gateway, &keys)?;

    let t0 = Instant::now();
    let served = drive_closed_loop(&gateway, &keys, n_requests, n_clients);
    let wall = t0.elapsed().as_secs_f64();

    // live telemetry snapshot while the gateway still serves — stats
    // are not a shutdown-only artifact
    println!("\n{}", gateway.stats().render());
    println!("throughput: {:.1} req/s aggregate ({wall:.2}s wall)\n", n_requests as f64 / wall);

    // per-session report: end-to-end latency percentiles + the accuracy
    // of the actually-served responses
    for (ki, key) in keys.iter().enumerate() {
        let net: Arc<_> = gateway.session(key).unwrap().network().clone();
        let mut lats: Vec<f64> = Vec::new();
        let mut rows: Vec<(usize, &[f32])> = Vec::new();
        for (k, sample, lat, logits) in &served {
            if k == &ki {
                lats.push(*lat);
                rows.push((*sample, logits.as_slice()));
            }
        }
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| {
            if lats.is_empty() { 0.0 } else { lats[((lats.len() - 1) as f64 * q) as usize] * 1e3 }
        };
        let logits: Vec<f32> = rows.iter().flat_map(|(_, l)| l.iter().copied()).collect();
        let labels: Vec<i32> = rows.iter().map(|(s, _)| net.eval_y[*s]).collect();
        let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
        println!(
            "{:<32} {} requests  p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms  top-{} acc {:.4}",
            key.to_string(),
            rows.len(),
            pct(0.5),
            pct(0.9),
            pct(0.99),
            net.topk,
            acc
        );
    }

    let stats = gateway.shutdown();
    println!(
        "\nshutdown: {} requests in {} batches across {} session(s)",
        stats.total_requests(),
        stats.total_batches(),
        stats.sessions.len()
    );
    Ok(())
}
