//! Serving-style driver: the PJRT-backed dynamic-batching inference
//! server under a closed-loop client population, reporting latency
//! percentiles, throughput and batching efficiency.
//!
//!     cargo run --release --example serve -- [--net lenet5] \
//!         [--format float:m10e6] [--requests 256] [--clients 8]

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use precis::coordinator::server::{InferenceServer, PjrtRunner};
use precis::eval::topk_accuracy;
use precis::formats::Format;
use precis::nn::Zoo;
use precis::runtime::Runtime;
use precis::util::cli::Args;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let net_name = args.get_or("net", "lenet5").to_string();
    let fmt = Format::parse(args.get_or("format", "float:m10e6"))?;
    let n_requests = args.get_usize("requests", 256)?;
    let n_clients = args.get_usize("clients", 8)?;
    let wait_ms = args.get_usize("wait-ms", 10)?;

    let zoo = Zoo::load("artifacts")?;
    let net = zoo.network(&net_name)?;
    let batch = zoo.batch;
    let dir = zoo.dir.clone();
    let kind = if fmt.is_float() { "float" } else { "fixed" };

    println!(
        "serving {net_name} @ {} (batch {batch}, {n_clients} closed-loop clients, {n_requests} requests)",
        fmt.id()
    );

    // PJRT handles are not Send: the runner is built on the dispatcher
    // thread via the factory.
    let net2 = net.clone();
    let kind2 = kind.to_string();
    let server = Arc::new(InferenceServer::spawn(
        net.clone(),
        batch,
        fmt,
        Duration::from_millis(wait_ms as u64),
        move || {
            let rt = Runtime::cpu()?;
            let model = rt.load_network(&net2, &dir, &kind2, batch)?;
            Ok(PjrtRunner { model })
        },
    ));

    let px: usize = net.input.iter().product();
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(n_requests);
    let mut predictions: Vec<(usize, Vec<f32>)> = Vec::with_capacity(n_requests);

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for cid in 0..n_clients {
            let server = server.clone();
            let net = net.clone();
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                let mut i = cid;
                while i < n_requests {
                    let sample = i % net.eval_len();
                    let pixels = net.eval_x.data()[sample * px..(sample + 1) * px].to_vec();
                    let t = Instant::now();
                    let logits = server.infer(pixels).expect("inference failed");
                    out.push((i, t.elapsed().as_secs_f64(), logits));
                    i += n_clients;
                }
                out
            }));
        }
        for h in handles {
            for (i, lat, logits) in h.join().unwrap() {
                latencies.push(lat);
                predictions.push((i, logits));
            }
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    // accuracy over the served responses
    predictions.sort_by_key(|(i, _)| *i);
    let classes = net.classes;
    let logits: Vec<f32> = predictions.iter().flat_map(|(_, l)| l.iter().copied()).collect();
    let labels: Vec<i32> = (0..n_requests).map(|i| net.eval_y[i % net.eval_len()]).collect();
    let acc = topk_accuracy(&logits, &labels, classes, net.topk);

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize] * 1e3;
    let stats = Arc::try_unwrap(server)
        .map(|s| s.shutdown())
        .unwrap_or_default();

    println!("\nresults:");
    println!("  throughput     : {:.1} req/s", n_requests as f64 / wall);
    println!("  latency p50    : {:.2} ms", pct(0.5));
    println!("  latency p90    : {:.2} ms", pct(0.9));
    println!("  latency p99    : {:.2} ms", pct(0.99));
    println!("  top-{} accuracy : {:.4}", net.topk, acc);
    println!(
        "  batches        : {} ({:.1} req/batch, {:.1}% padded slots)",
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        100.0 * stats.padded_slots as f64 / (stats.batches.max(1) * batch as u64) as f64
    );
    Ok(())
}
