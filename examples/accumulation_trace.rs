//! Figure 8 study: serialized accumulation of one real neuron's weighted
//! inputs under several customized-precision formats, with an ASCII
//! rendering of the trajectories and the saturation events.
//!
//!     cargo run --release --example accumulation_trace [-- <network> <sample>]

use anyhow::Result;

use precis::figures::{fig8_formats, neuron_chain};
use precis::nn::Zoo;
use precis::numerics::trace::{trace_accumulation, trace_exact};

/// Repo-root artifacts dir, valid from any cwd (matches tests/benches).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let net_name = args.first().map(|s| s.as_str()).unwrap_or("alexnet-mini");
    let sample: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0);

    let zoo = Zoo::load(ARTIFACTS)?;
    let net = zoo.network(net_name)?;
    let (weights, inputs) = neuron_chain(&net, sample)?;
    println!(
        "neuron: deepest conv of {net_name}, center position, out-channel 0; \
         chain length {} (eval sample {sample})\n",
        weights.len()
    );

    let exact = trace_exact(&weights, &inputs);
    let fmts = fig8_formats();
    let traces: Vec<_> = fmts
        .iter()
        .map(|f| trace_accumulation(&weights, &inputs, f))
        .collect();

    // table every ~K/16 steps
    print!("{:>6} {:>12}", "step", "exact");
    for f in &fmts {
        print!(" {:>14}", f.id());
    }
    println!();
    let n = exact.len();
    for step in (0..n).step_by((n / 16).max(1)).chain([n - 1]) {
        print!("{:>6} {:>12.5}", step, exact[step]);
        for t in &traces {
            print!(" {:>14.5}", t.running[step]);
        }
        println!();
    }

    println!("\nfinal values & saturation:");
    println!("  {:<16} final {:>12.5}", "exact(f32)", exact[n - 1]);
    for t in &traces {
        println!(
            "  {:<16} final {:>12.5}   first saturation: {}",
            t.format.id(),
            t.final_value,
            t.first_saturation
                .map(|s| format!("step {s}"))
                .unwrap_or_else(|| "never".into()),
        );
    }

    // ASCII trajectory of exact vs the most error-prone format
    println!("\ntrajectory (x = exact, o = {}):", fmts[0].id());
    let rows = 14usize;
    let cols = 72usize;
    let all: Vec<f32> = exact.iter().chain(traces[0].running.iter()).copied().collect();
    let lo = all.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = all.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-6);
    let mut grid = vec![vec![b' '; cols]; rows];
    for (series, ch) in [(&exact, b'x'), (&traces[0].running, b'o')] {
        for (i, &v) in series.iter().enumerate() {
            let cx = i * (cols - 1) / (n - 1).max(1);
            let cy = ((v - lo) / span * (rows - 1) as f32).round() as usize;
            grid[rows - 1 - cy.min(rows - 1)][cx] = ch;
        }
    }
    for row in grid {
        println!("  |{}", String::from_utf8_lossy(&row));
    }
    println!("  +{}", "-".repeat(cols));
    Ok(())
}
