//! Quickstart: load the zoo, evaluate a few customized-precision
//! configurations on LeNet-5, and print the accuracy/efficiency
//! trade-off.  Run with:
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use precis::eval::accuracy;
use precis::formats::Format;
use precis::hw;
use precis::nn::Zoo;

/// Repo-root artifacts dir, valid from any cwd (matches tests/benches).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn main() -> Result<()> {
    let zoo = Zoo::load(ARTIFACTS)?;
    let net = zoo.network("lenet5")?;
    println!(
        "network: {} ({} params, longest MAC chain {})\n",
        net.name, net.n_params, net.max_chain
    );

    println!(
        "{:<14} {:>6} {:>9} {:>9} {:>9}",
        "format", "bits", "top-1", "speedup", "energy"
    );
    for fmt in [
        Format::SINGLE,
        Format::float(10, 6),
        Format::float(7, 6),
        Format::float(4, 5),
        Format::float(2, 3),
        Format::fixed(8, 8),
        Format::fixed(4, 6),
        Format::fixed(2, 2),
    ] {
        let acc = accuracy(&net, &fmt, 128)?;
        println!(
            "{:<14} {:>6} {:>9.3} {:>8.2}x {:>8.2}x",
            fmt.id(),
            fmt.total_bits(),
            acc,
            hw::speedup(&fmt),
            hw::energy_savings(&fmt),
        );
    }

    println!(
        "\nThe sweet spot keeps accuracy at the baseline while running\n\
         several times faster — the paper's core observation.  Run the\n\
         precision_search example for the full §3.3 pipeline."
    );
    Ok(())
}
