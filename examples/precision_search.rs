//! END-TO-END DRIVER — the full paper pipeline on the real (small)
//! workload, proving all layers compose:
//!
//! 1. loads the AOT-trained model zoo (built by `make artifacts` — JAX
//!    training + Pallas-kernel HLO lowering, Python never runs again);
//! 2. cross-validates the §3.3 accuracy model (fit on the other
//!    reference networks, never on the network under search);
//! 3. runs the model-driven precision search (10-input probes + 2
//!    refinement evaluations) for every network over the full design
//!    space, on the native engine;
//! 4. validates the chosen configuration END-TO-END through the PJRT
//!    path (the AOT artifact), confirming the two backends agree;
//! 5. reports the Fig 11 table and the paper's headline metric: mean
//!    speedup at <1% accuracy degradation.
//!
//!     cargo run --release --example precision_search [-- --samples 128]
//!
//! The full run is recorded in EXPERIMENTS.md.

use anyhow::Result;

use precis::coordinator::cache::ResultCache;
use precis::coordinator::Coordinator;
use precis::eval::sweep::EvalOptions;
use precis::eval::topk_accuracy;
use precis::figures::cross_validated_model;
use precis::formats;
use precis::nn::Zoo;
use precis::runtime::Runtime;
use precis::search::{search, SearchSpec};
use precis::util::cli::Args;
use precis::util::timer::Timer;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let samples = args.get_usize("samples", 128)?;
    let seed = args.get_usize("seed", 2018)? as u64;
    let opts = EvalOptions { samples, batch: 32 };

    let t_total = Timer::start();
    let zoo = Zoo::load("artifacts")?;
    let cache = ResultCache::open("results/cache.json");
    let coord = Coordinator::new(zoo, cache);
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}\n", rt.platform());

    println!(
        "{:<16} {:>8} {:<14} {:>9} {:>9} {:>10} {:>12}",
        "network", "params", "chosen", "speedup", "energy", "norm_acc", "pjrt_agrees"
    );

    let mut speedups: Vec<f64> = Vec::new();
    let mut deployable: Vec<f64> = Vec::new();
    for net in coord.zoo.by_size_desc() {
        let t = Timer::start();
        let model = cross_validated_model(&coord, &net.name, &opts, seed)?;
        let spec = SearchSpec {
            formats: formats::design_space(1),
            target: 0.99,
            refine_samples: 2,
            opts,
            seed,
        };
        let out = search(&net, &spec, &model);
        let Some(chosen) = out.chosen else {
            println!("{:<16} -- no configuration met the target --", net.name);
            continue;
        };

        // end-to-end validation through the AOT/PJRT path
        let kind = if chosen.is_float() { "float" } else { "fixed" };
        let loaded = rt.load_network(&net, &coord.zoo.dir, kind, coord.zoo.batch)?;
        let (logits, labels) = loaded.run_eval(samples, &chosen)?;
        let pjrt_acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
        let native_acc = precis::eval::accuracy(&net, &chosen, samples)?;
        let agrees = (pjrt_acc - native_acc).abs() < 1e-12;

        println!(
            "{:<16} {:>8} {:<14} {:>8.2}x {:>8.2}x {:>10.4} {:>12} ({:.0}s)",
            net.name,
            net.n_params,
            chosen.id(),
            out.speedup,
            precis::hw::energy_savings(&chosen),
            out.measured_norm_acc,
            if agrees { "yes" } else { "NO" },
            t.elapsed_s(),
        );
        assert!(agrees, "PJRT and native disagree on {}", net.name);

        speedups.push(out.speedup);
        if matches!(net.name.as_str(), "googlenet-mini" | "vgg-mini" | "alexnet-mini") {
            deployable.push(out.speedup);
        }
    }
    coord.cache.flush()?;

    let gmean = |v: &[f64]| (v.iter().map(|s| s.ln()).sum::<f64>() / v.len() as f64).exp();
    println!("\nheadline (paper: 7.6x average at <1% degradation on deployable DNNs):");
    println!(
        "  mean speedup, all 5 networks      : {:.2}x (geo {:.2}x)",
        speedups.iter().sum::<f64>() / speedups.len() as f64,
        gmean(&speedups)
    );
    if !deployable.is_empty() {
        println!(
            "  mean speedup, deployable networks : {:.2}x (geo {:.2}x)",
            deployable.iter().sum::<f64>() / deployable.len() as f64,
            gmean(&deployable)
        );
    }
    println!("\ntotal wall-clock: {:.0}s", t_total.elapsed_s());
    Ok(())
}
