//! END-TO-END DRIVER — the full paper pipeline on the real (small)
//! workload, proving all layers compose:
//!
//! 1. loads the AOT-trained model zoo (built by `make artifacts` — JAX
//!    training + Pallas-kernel HLO lowering, Python never runs again);
//! 2. cross-validates the §3.3 accuracy model (fit on the other
//!    reference networks, never on the network under search);
//! 3. runs the model-driven precision search (10-input probes + 2
//!    refinement evaluations) for every network over the full design
//!    space, on the native engine;
//! 4. validates the chosen configuration END-TO-END through the PJRT
//!    path (the AOT artifact), confirming the two backends agree —
//!    `pjrt` feature builds only, otherwise reported as skipped
//!    (DESIGN.md §5);
//! 5. reports the Fig 11 table and the paper's headline metric: mean
//!    speedup at <1% accuracy degradation.
//!
//!     cargo run --release --example precision_search [-- --samples 128]
//!
//! The full run is recorded in EXPERIMENTS.md.

use anyhow::Result;

use precis::coordinator::cache::ResultCache;
use precis::coordinator::Coordinator;
use precis::eval::sweep::EvalOptions;
use precis::figures::cross_validated_model;
use precis::formats::{self, Format};
use precis::nn::{Network, Zoo};
use precis::search::{search, SearchSpec};
use precis::util::cli::Args;
use precis::util::timer::Timer;

/// Repo-root artifacts/results dirs, valid from any cwd (matches
/// tests/benches).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
const CACHE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../results/cache.json");

/// One PJRT client for the whole run (PJRT clients are one-per-process;
/// see `runtime/pjrt.rs`).  `accuracy` returns `Ok(None)` only for "no
/// usable PJRT runtime" (feature off, or the client cannot start —
/// e.g. the offline `xla` stub), reported as a skip; a runtime that
/// *does* start but then fails to load or execute the artifact is a
/// real error and propagates — a broken artifact must not be
/// indistinguishable from a native-only build.
#[cfg(feature = "pjrt")]
struct PjrtValidator {
    rt: Option<precis::runtime::Runtime>,
}

#[cfg(feature = "pjrt")]
impl PjrtValidator {
    fn new() -> PjrtValidator {
        match precis::runtime::Runtime::cpu() {
            Ok(rt) => PjrtValidator { rt: Some(rt) },
            Err(e) => {
                eprintln!("(PJRT unavailable: {e:#})");
                PjrtValidator { rt: None }
            }
        }
    }

    fn accuracy(
        &self,
        net: &std::sync::Arc<Network>,
        coord: &Coordinator,
        chosen: &Format,
        samples: usize,
    ) -> Result<Option<f64>> {
        use precis::eval::topk_accuracy;
        let Some(rt) = &self.rt else { return Ok(None) };
        let kind = if chosen.is_float() { "float" } else { "fixed" };
        let loaded = rt.load_network(net, &coord.zoo.dir, kind, coord.zoo.batch)?;
        let (logits, labels) = loaded.run_eval(samples, chosen)?;
        Ok(Some(topk_accuracy(&logits, &labels, net.classes, net.topk)))
    }
}

#[cfg(not(feature = "pjrt"))]
struct PjrtValidator;

#[cfg(not(feature = "pjrt"))]
impl PjrtValidator {
    fn new() -> PjrtValidator {
        PjrtValidator
    }

    fn accuracy(
        &self,
        _net: &std::sync::Arc<Network>,
        _coord: &Coordinator,
        _chosen: &Format,
        _samples: usize,
    ) -> Result<Option<f64>> {
        Ok(None)
    }
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let samples = args.get_usize("samples", 128)?;
    let seed = args.get_usize("seed", 2018)? as u64;
    let opts = EvalOptions { samples, batch: 32 };

    let t_total = Timer::start();
    let zoo = Zoo::load(ARTIFACTS)?;
    let cache = ResultCache::open(CACHE);
    let coord = Coordinator::new(zoo, cache);
    if !precis::runtime::AVAILABLE {
        println!("(native-only build: PJRT validation reported as `skip` — DESIGN.md §5)\n");
    }
    let validator = PjrtValidator::new();

    println!(
        "{:<16} {:>8} {:<14} {:>9} {:>9} {:>10} {:>12}",
        "network", "params", "chosen", "speedup", "energy", "norm_acc", "pjrt_agrees"
    );

    let mut speedups: Vec<f64> = Vec::new();
    let mut deployable: Vec<f64> = Vec::new();
    for net in coord.zoo.by_size_desc() {
        let t = Timer::start();
        let model = cross_validated_model(&coord, &net.name, &opts, seed)?;
        let spec = SearchSpec {
            formats: formats::design_space(1),
            target: 0.99,
            refine_samples: 2,
            opts,
            seed,
        };
        let out = search(&net, &spec, &model)?;
        let Some(chosen) = out.chosen else {
            println!("{:<16} -- no configuration met the target --", net.name);
            continue;
        };

        // end-to-end validation through the AOT/PJRT path, when available
        let native_acc = precis::eval::accuracy(&net, &chosen, samples)?;
        let pjrt_acc = validator.accuracy(&net, &coord, &chosen, samples)?;
        let ok = pjrt_acc.map(|p| (p - native_acc).abs() < 1e-12);
        let agrees = match ok {
            Some(true) => "yes",
            Some(false) => "NO",
            None => "skip",
        };

        // print the row before failing on disagreement, so the numbers
        // a mismatch needs debugging with are on screen
        println!(
            "{:<16} {:>8} {:<14} {:>8.2}x {:>8.2}x {:>10.4} {:>12} ({:.0}s)",
            net.name,
            net.n_params,
            chosen.id(),
            out.speedup,
            precis::hw::energy_savings(&chosen),
            out.measured_norm_acc,
            agrees,
            t.elapsed_s(),
        );
        if ok == Some(false) {
            anyhow::bail!(
                "PJRT and native disagree on {}: pjrt {:?} vs native {native_acc}",
                net.name,
                pjrt_acc
            );
        }

        speedups.push(out.speedup);
        if matches!(net.name.as_str(), "googlenet-mini" | "vgg-mini" | "alexnet-mini") {
            deployable.push(out.speedup);
        }
    }
    coord.cache.flush()?;

    let gmean = |v: &[f64]| (v.iter().map(|s| s.ln()).sum::<f64>() / v.len() as f64).exp();
    println!("\nheadline (paper: 7.6x average at <1% degradation on deployable DNNs):");
    println!(
        "  mean speedup, all 5 networks      : {:.2}x (geo {:.2}x)",
        speedups.iter().sum::<f64>() / speedups.len() as f64,
        gmean(&speedups)
    );
    if !deployable.is_empty() {
        println!(
            "  mean speedup, deployable networks : {:.2}x (geo {:.2}x)",
            deployable.iter().sum::<f64>() / deployable.len() as f64,
            gmean(&deployable)
        );
    }
    println!("\ntotal wall-clock: {:.0}s", t_total.elapsed_s());
    Ok(())
}
